"""The real host-async PS runtime: record-and-replay, stragglers, traces.

The contracts under test:
  * record-and-replay — a threaded W=4 run's realized (k(j), ticket) trace,
    replayed through ``Trainer.scan_with``, reproduces the identical forest
    bit for bit (the runtime's debuggability story);
  * the realized schedule is a valid causal k(j) and the tickets are a
    permutation of the rounds;
  * straggler injection — a slow worker's pushes are measurably more stale,
    and training still converges;
  * trace JSON round-trips, and the simulator cross-validation helpers
    compare realized vs. predicted staleness for the measured geometry.
"""
import json

import numpy as np
import pytest

from repro.core.sgbdt import SGBDTConfig, init_state, train_loss
from repro.core.simulator import crossvalidate_schedule, staleness_stats
from repro.ps import AsyncRuntime, RunTrace, replay_trace, resolve_schedule
from repro.trees.learner import LearnerConfig


@pytest.fixture(scope="module")
def rt_cfg():
    return SGBDTConfig(
        n_trees=24, step_length=0.3, sampling_rate=0.8,
        learner=LearnerConfig(depth=4, n_bins=64),
    )


def _forest_identical(a, b) -> bool:
    return (
        np.array_equal(np.asarray(a.forest.feature), np.asarray(b.forest.feature))
        and np.array_equal(
            np.asarray(a.forest.threshold), np.asarray(b.forest.threshold)
        )
        and np.array_equal(
            np.asarray(a.forest.leaf_value), np.asarray(b.forest.leaf_value)
        )
        and np.array_equal(np.asarray(a.f), np.asarray(b.f))
    )


@pytest.fixture(scope="module")
def threaded_run(rt_cfg, sparse_data):
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4)
    state, trace = rt.run(seed=0)
    return rt, state, trace


def test_record_and_replay_identical_forest(rt_cfg, sparse_data, threaded_run):
    """THE runtime contract: the nondeterministic threaded interleaving,
    replayed from its trace through the deterministic fused-scan engine,
    rebuilds the same model exactly."""
    rt, state, trace = threaded_run
    st_replay, losses = rt.replay(trace)
    assert _forest_identical(state, st_replay)
    assert losses.shape == (rt_cfg.n_trees,)
    # and through the module-level entry point (fresh Trainer, same result)
    st_again, _ = replay_trace(rt_cfg, sparse_data, trace)
    assert _forest_identical(state, st_again)


def test_trace_is_valid_schedule(rt_cfg, threaded_run):
    _, _, trace = threaded_run
    # causal, non-negative, right length — resolve_schedule enforces all
    resolve_schedule(trace.schedule, rt_cfg.n_trees)
    assert sorted(trace.key_index.tolist()) == list(range(rt_cfg.n_trees))
    assert set(trace.worker.tolist()) <= set(range(4))
    assert trace.makespan > 0
    assert (trace.t_build > 0).all()
    hist = trace.staleness_histogram()
    assert sum(hist.values()) == rt_cfg.n_trees


def test_trace_json_roundtrip(tmp_path, threaded_run):
    _, _, trace = threaded_run
    path = trace.save(tmp_path / "trace.json")
    back = RunTrace.load(path)
    assert back.n_workers == trace.n_workers and back.seed == trace.seed
    np.testing.assert_array_equal(back.schedule, trace.schedule)
    np.testing.assert_array_equal(back.key_index, trace.key_index)
    np.testing.assert_array_equal(back.worker, trace.worker)
    np.testing.assert_allclose(back.t_build, trace.t_build)
    assert back.makespan == pytest.approx(trace.makespan)


def test_replayed_loaded_trace_matches(rt_cfg, sparse_data, threaded_run, tmp_path):
    """Replay survives serialization: a trace loaded from disk still
    reproduces the threaded forest."""
    _, state, trace = threaded_run
    back = RunTrace.load(trace.save(tmp_path / "t.json"))
    st_replay, _ = replay_trace(rt_cfg, sparse_data, back)
    assert _forest_identical(state, st_replay)


def test_straggler_shifts_staleness(rt_cfg, sparse_data):
    """One slow worker: its pushes are built from older versions than the
    fast workers' (it holds each snapshot longer), and bounded staleness
    still converges — the paper's validity claim under heterogeneity."""
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4, worker_delay={0: 0.25})
    state, trace = rt.run(seed=0)
    stale = trace.staleness
    from_straggler = trace.worker == 0
    assert from_straggler.any(), "straggler never pushed"
    assert from_straggler.sum() < (~from_straggler).sum()
    assert stale[from_straggler].mean() > stale[~from_straggler].mean()
    # still trains: loss strictly improves on the init state
    l0 = float(train_loss(rt_cfg, sparse_data, init_state(rt_cfg, sparse_data)))
    l1 = float(train_loss(rt_cfg, sparse_data, state))
    assert l1 < 0.9 * l0


def test_crossvalidation_helpers(threaded_run):
    _, _, trace = threaded_run
    stats = staleness_stats(trace.schedule)
    assert stats["mean_staleness"] == pytest.approx(float(trace.staleness.mean()))
    assert sum(stats["histogram"].values()) == trace.n_trees
    xval = crossvalidate_schedule(
        trace.schedule, trace.cluster_spec(), makespan=trace.makespan
    )
    assert xval["realized"]["mean_staleness"] == stats["mean_staleness"]
    assert xval["simulated"]["max_staleness"] >= 0
    assert xval["realized_makespan"] == pytest.approx(trace.makespan)
    assert xval["makespan_ratio"] > 0


def test_multioutput_replay():
    """K-output rounds (stacked tree groups, one push each) ride the same
    runtime + replay contract."""
    import repro.data as D

    data = D.make_multiclass_classification(300, 20, 3, seed=11)
    cfg = SGBDTConfig(
        n_trees=10, step_length=0.2, sampling_rate=0.9,
        objective="multiclass:3",
        learner=LearnerConfig(depth=3, n_bins=64),
    )
    rt = AsyncRuntime(cfg, data, n_workers=3)
    state, trace = rt.run(seed=1)
    st_replay, _ = rt.replay(trace)
    assert _forest_identical(state, st_replay)
    assert int(state.forest.n_trees) == 30  # 10 rounds x 3 outputs


def test_runtime_rejects_bad_args(rt_cfg, sparse_data):
    with pytest.raises(ValueError):
        AsyncRuntime(rt_cfg, sparse_data, n_workers=0)
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=2)
    _, trace = rt.run(seed=0)
    wrong = SGBDTConfig(
        n_trees=rt_cfg.n_trees + 1, step_length=0.3, sampling_rate=0.8,
        learner=LearnerConfig(depth=4, n_bins=64),
    )
    with pytest.raises(ValueError):
        replay_trace(wrong, sparse_data, trace)


@pytest.mark.slow
@pytest.mark.parametrize("hist_mode", ["subtract", "rebuild"])
def test_train_cli_threads_verify_replay(hist_mode, tmp_path):
    """Subprocess smoke of the full CLI path: ``launch.train --runtime
    threads --verify-replay`` must hold the bitwise replay contract under
    BOTH histogram modes (the driver asserts it in-process and exits
    nonzero on drift), and must export a loadable trace."""
    import os
    import pathlib
    import subprocess
    import sys

    trace_path = tmp_path / f"trace_{hist_mode}.json"
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.train", "--arch", "gbdt",
            "--runtime", "threads", "--steps", "6", "--workers", "2",
            "--hist-mode", hist_mode, "--verify-replay",
            "--trace-out", str(trace_path),
        ],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": str(src), "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "record-and-replay identical forest: True" in proc.stdout
    trace = RunTrace.load(trace_path)
    assert trace.n_trees == 6
    resolve_schedule(trace.schedule, 6)  # valid causal k(j)


# ---------------------------------------------------- elastic + fault injection
from repro.checkpoint import steps as ckpt_steps  # noqa: E402
from repro.ps import FaultPlan  # noqa: E402


@pytest.fixture(scope="module")
def fault_run(rt_cfg, sparse_data):
    """W=4 with a crash (ticket 5), a graceful leave (ticket 9), and a
    rejoin at fold 10 — the canonical elastic run."""
    plan = FaultPlan(crash_tickets={5}, leave_tickets={9}, join_at={7: 10})
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4, faults=plan)
    state, trace = rt.run(seed=0)
    return rt, state, trace


def test_fault_plan_validation(rt_cfg, sparse_data):
    with pytest.raises(ValueError):
        FaultPlan(crash_tickets={3}, leave_tickets={3})
    with pytest.raises(ValueError):
        FaultPlan(crash_tickets={-1})
    with pytest.raises(ValueError):
        FaultPlan(join_at={1: -2})
    with pytest.raises(ValueError):  # join threshold past the end of the run
        AsyncRuntime(
            rt_cfg, sparse_data, n_workers=2,
            faults=FaultPlan(join_at={5: rt_cfg.n_trees + 1}),
        )


def test_membership_events_recorded(rt_cfg, fault_run):
    """The fault plan's effects are all in the trace: one crash at ticket 5,
    one leave at ticket 9, one join of worker 7, each opening a new epoch."""
    _, _, trace = fault_run
    by_kind = {e["kind"]: e for e in trace.events}
    assert set(by_kind) == {"crash", "leave", "join"}
    assert by_kind["crash"]["ticket"] == 5
    assert by_kind["leave"]["ticket"] == 9
    assert by_kind["join"]["worker"] == 7 and by_kind["join"]["fold"] >= 10
    assert trace.n_epochs == 4  # initial + one per event
    assert trace.epoch.max() == 3 and trace.epoch.min() == 0
    # the crashed ticket was re-issued: the permutation is still complete
    assert sorted(trace.key_index.tolist()) == list(range(rt_cfg.n_trees))
    assert (trace.key_index.tolist()).count(5) == 1
    # the joined worker really worked
    assert 7 in set(trace.worker.tolist())
    assert trace.membership_deltas() == [
        (by_kind["crash"]["fold"], -1),
        (by_kind["leave"]["fold"], -1),
        (by_kind["join"]["fold"], 1),
    ]


def test_elastic_trace_replays_bitwise(rt_cfg, sparse_data, fault_run):
    """THE tentpole contract: membership churn only decides which worker
    realizes each (k(j), ticket) row — the trace still replays exactly."""
    _, state, trace = fault_run
    st_replay, _ = replay_trace(rt_cfg, sparse_data, trace)
    assert _forest_identical(state, st_replay)


def test_fault_plan_is_deterministic(rt_cfg, sparse_data):
    """Crash/leave key off ticket numbers, not timing: two runs under the
    same plan produce the same membership event set (worker attribution of
    the crash may differ — that is the race — but never what happened)."""
    plan = FaultPlan(crash_tickets={2}, leave_tickets={6})
    traces = []
    for _ in range(2):
        rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=3, faults=plan)
        _, trace = rt.run(seed=0)
        traces.append(trace)
    for t in traces:
        assert [(e["kind"], e["ticket"]) for e in t.events] == [
            ("crash", 2), ("leave", 6),
        ]
        assert sorted(t.key_index.tolist()) == list(range(rt_cfg.n_trees))


def test_all_workers_dead_is_a_loud_error(rt_cfg, sparse_data):
    """Killing every worker with no rejoin must raise, not hang."""
    plan = FaultPlan(crash_tickets={0, 1})
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=2, faults=plan)
    with pytest.raises(RuntimeError, match="no live workers"):
        rt.run(seed=0)


# ------------------------------------------------------------- trace schema v2
def test_trace_v1_still_loads(tmp_path, threaded_run):
    """Back-compat: a v1 trace (pre-elastic schema) loads with defaulted
    v2 columns — one epoch, no events, unit step scales."""
    _, _, trace = threaded_run
    d = trace.to_json()
    d["trace_version"] = 1
    for v2_only in ("epoch", "pull_bytes", "step_scale", "events",
                    "n_parts", "full_pull_bytes", "adaptive_rho"):
        d.pop(v2_only)
    path = tmp_path / "v1.json"
    path.write_text(json.dumps(d))
    back = RunTrace.load(path)
    np.testing.assert_array_equal(back.schedule, trace.schedule)
    assert back.events == () and back.n_epochs == 1
    assert (back.step_scale == 1.0).all()
    assert back.adaptive_rho == 0.0


def test_trace_unknown_version_fails_loudly(tmp_path, threaded_run):
    _, _, trace = threaded_run
    d = trace.to_json()
    d["trace_version"] = 99
    path = tmp_path / "bad.json"
    path.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="unknown RunTrace schema version"):
        RunTrace.load(path)
    d.pop("trace_version")
    path.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="unknown RunTrace schema version"):
        RunTrace.load(path)


def test_trace_unknown_field_fails_loudly(tmp_path, threaded_run):
    """A field no schema version defines is data the replay would silently
    drop — refuse it for every version."""
    _, _, trace = threaded_run
    for version in (1, 2):
        d = trace.to_json()
        if version == 1:
            for v2_only in ("epoch", "pull_bytes", "step_scale", "events",
                            "n_parts", "full_pull_bytes", "adaptive_rho"):
                d.pop(v2_only)
        d["trace_version"] = version
        d["mystery"] = 1
        path = tmp_path / f"bad_{version}.json"
        path.write_text(json.dumps(d))
        with pytest.raises(ValueError, match="mystery"):
            RunTrace.load(path)


# ------------------------------------------------------------- sharded pulls
def test_sharded_pulls_reduce_bytes_and_replay_bitwise(rt_cfg, sparse_data):
    """Partition-granular pulls move measurably fewer bytes, and the run
    still replays bitwise through the FULL-table deterministic engine —
    the masked rows are exactly the m' = 0 rows, which are inert."""
    n = sparse_data.n_samples
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4, shard_pulls=n)
    state, trace = rt.run(seed=0)
    assert trace.n_parts == n
    full = 4 * rt_cfg.obj.n_outputs * n
    assert trace.full_pull_bytes == full
    assert float(trace.pull_bytes.mean()) < full
    assert trace.summary()["pull_reduction"] > 0.05
    st_replay, _ = replay_trace(rt_cfg, sparse_data, trace)
    assert _forest_identical(state, st_replay)


def test_sharded_pulls_gated_to_rowwise_objectives():
    """LambdaRank mixes rows within a query group: a worker cannot know
    its gradient from a partial F, so sharded pulls must refuse it."""
    import repro.data as D

    data = D.make_ranking(8, 16, 40, seed=0)
    cfg = SGBDTConfig(
        n_trees=4, step_length=0.2, sampling_rate=0.9,
        objective="lambdarank", learner=LearnerConfig(depth=3, n_bins=32),
    )
    with pytest.raises(ValueError, match="not rowwise"):
        AsyncRuntime(cfg, data, n_workers=2, shard_pulls=4)


def test_sharded_pulls_bounds(rt_cfg, sparse_data):
    with pytest.raises(ValueError, match="shard_pulls"):
        AsyncRuntime(rt_cfg, sparse_data, n_workers=2,
                     shard_pulls=sparse_data.n_samples + 1)


# ------------------------------------------------------------- crash-resume
def test_halt_resume_replay_parity(rt_cfg, sparse_data, tmp_path):
    """The crash-resume contract end to end: halt mid-run (simulated
    process crash), resume from the on-disk trace prefix + checkpoints,
    and require (a) the combined trace replays bitwise from scratch and
    (b) the final state rebuilds bitwise from checkpoint + trace suffix."""
    ck = tmp_path / "ck"
    tr = tmp_path / "trace.json"
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4)
    _, prefix = rt.run(
        seed=0, checkpoint_dir=ck, checkpoint_every=5,
        halt_at_fold=13, trace_path=tr,
    )
    assert prefix.n_trees == 13
    assert ckpt_steps(ck) == [5, 10, 13]
    on_disk = RunTrace.load(tr)  # the crash leaves a loadable prefix
    np.testing.assert_array_equal(on_disk.schedule, prefix.schedule)

    rt2 = AsyncRuntime(rt_cfg, sparse_data, n_workers=4)
    state, combined = rt2.resume(on_disk, ck)
    assert combined.n_trees == rt_cfg.n_trees
    # prefix rows are verbatim; the seam is a recorded resume event
    np.testing.assert_array_equal(combined.schedule[:13], prefix.schedule)
    np.testing.assert_array_equal(combined.key_index[:13], prefix.key_index)
    assert combined.events[-1]["kind"] == "resume"
    assert combined.events[-1]["fold"] == 13
    # (a) deterministic replay of the combined trace
    st_replay, _ = replay_trace(rt_cfg, sparse_data, combined)
    assert _forest_identical(state, st_replay)
    # (b) checkpoint + suffix replay (the 13-fold checkpoint serves the
    # stale versions the in-flight builds held at the halt)
    st_ckpt = rt2.replay_from_checkpoint(ck, combined)
    assert _forest_identical(state, st_ckpt)


def test_resume_reissues_lost_inflight_tickets(rt_cfg, sparse_data, tmp_path):
    """Tickets in flight at the crash (issued, never folded) are exactly
    the ones the resumed run re-issues — nothing lost, nothing doubled."""
    ck = tmp_path / "ck"
    rt = AsyncRuntime(rt_cfg, sparse_data, n_workers=4)
    _, prefix = rt.run(
        seed=0, checkpoint_dir=ck, checkpoint_every=6, halt_at_fold=9
    )
    folded = set(prefix.key_index.tolist())
    rt2 = AsyncRuntime(rt_cfg, sparse_data, n_workers=2)  # elastic: W=4 -> 2
    _, combined = rt2.resume(prefix, ck)
    suffix = combined.key_index[9:].tolist()
    assert sorted(suffix) == sorted(set(range(rt_cfg.n_trees)) - folded)
    assert set(combined.worker[9:].tolist()) <= {0, 1}
    # resume without a usable checkpoint fails loudly
    with pytest.raises(ValueError, match="no checkpoint"):
        rt2.resume(prefix, tmp_path / "empty")
    # a complete trace has nothing to resume
    with pytest.raises(ValueError, match="nothing to resume"):
        rt2.resume(combined, ck)


# ------------------------------------------------------------- adaptive step
def test_adaptive_step_scales_recorded_and_replayed(rt_cfg, sparse_data):
    """rho > 0: the server deflates each fold by 1/(1+6*rho*tau) at fold
    time, the realized scales land in the trace, and the fused replay
    computes the identical f32 scales — still bitwise."""
    acfg = rt_cfg._replace(adaptive_step=0.05)
    rt = AsyncRuntime(acfg, sparse_data, n_workers=4)
    state, trace = rt.run(seed=0)
    assert trace.adaptive_rho == 0.05
    tau = trace.staleness.astype(np.float32)
    expect = np.float32(1.0) / (np.float32(1.0) + np.float32(6.0 * 0.05) * tau)
    np.testing.assert_array_equal(trace.step_scale, expect)
    assert (trace.step_scale[tau > 0] < 1.0).all()
    st_replay, _ = replay_trace(acfg, sparse_data, trace)
    assert _forest_identical(state, st_replay)
    # replaying under a different rho is refused: the folds would differ
    with pytest.raises(ValueError, match="adaptive_rho"):
        replay_trace(rt_cfg, sparse_data, trace)
