"""Quickstart: train a stochastic GBDT serially, then asynchronously with 16
workers, and verify the paper's headline claim — on a high-diversity sparse
dataset, asynchrony does not slow per-epoch convergence.

    PYTHONPATH=src python examples/quickstart.py

Beyond the binary default, ``SGBDTConfig(objective=...)`` accepts any
registered objective spec — "mse", "quantile:0.9", "huber",
"multiclass:K" (K trees per round), "lambdarank" — and
``repro.launch.train --arch gbdt --objective ...`` drives each on a
matched synthetic workload.
"""
import numpy as np

import repro.data as D
from repro.core.async_sgbdt import train_async, worker_round_robin
from repro.core.baselines import max_workers_bound, speedup_model_async
from repro.core.sgbdt import SGBDTConfig, train_loss, train_serial
from repro.trees.learner import LearnerConfig


def main():
    # 1. A real-sim-like dataset: high-dimensional, sparse, every sample
    #    distinct (the regime the paper's requirements favor).
    data = D.make_sparse_classification(n=2000, dim=600, nnz=15, seed=0)
    cfg = SGBDTConfig(
        n_trees=150,
        step_length=0.2,
        sampling_rate=0.8,  # the paper's R_ij
        learner=LearnerConfig(depth=5, n_bins=64, feature_fraction=0.8),
    )

    # 2. Serial baseline (Friedman's stochastic GBDT).
    st_serial = train_serial(cfg, data, seed=0)
    l_serial = float(train_loss(cfg, data, st_serial))

    # 3. Asynch-SGBDT: 16 workers as a delay schedule k(j) = j - 15.
    st_async = train_async(cfg, data, worker_round_robin(cfg.n_trees, 16), seed=0)
    l_async = float(train_loss(cfg, data, st_async))

    print(f"serial  loss after {cfg.n_trees} trees: {l_serial:.4f}")
    print(f"async16 loss after {cfg.n_trees} trees: {l_async:.4f}")
    print(f"per-epoch penalty of asynchrony: {l_async - l_serial:+.4f} "
          "(paper: ~0 on sparse data)")

    # 4. What speedup would those 16 workers buy? (Eq. 13)
    t_build, t_comm, t_server = 0.1, 0.004, 0.008  # measured in fig10 bench
    s = speedup_model_async(np.array([16]), t_build, t_comm, t_server)[0]
    print(f"Eq. 13 speedup at 16 workers: {s:.1f}x "
          f"(server saturates at ~{max_workers_bound(t_build, t_comm, t_server):.0f} workers)")


if __name__ == "__main__":
    main()
