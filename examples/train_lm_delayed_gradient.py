"""The paper's two mechanisms — Bernoulli importance sampling + delayed
(stale) gradients with Prop.-1 step scaling — applied to LM training on any
assigned architecture.

    PYTHONPATH=src python examples/train_lm_delayed_gradient.py \
        [--arch granite-3-2b] [--delay 4] [--steps 120]

Compares three optimizer regimes on the same data stream:
  fresh       — standard AdamW (tau = 0)
  stale       — gradients delayed by tau, same lr  (diverges/oscillates)
  stale+prop1 — gradients delayed by tau, lr scaled per Proposition 1
"""
import argparse

import jax
import numpy as np

import repro.configs as configs
import repro.models as M
import repro.optim as O
from repro.launch.steps import make_train_step
from repro.launch.train import synthetic_batches


def run(cfg, opt, steps, sample, seed=0):
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, sampling_rate=sample))
    losses = []
    for i, batch in enumerate(synthetic_batches(cfg, 8, 64, steps, seed=seed)):
        params, state, m = step(params, state, batch, jax.random.fold_in(key, i))
        losses.append(float(m["loss"]))
    return np.asarray(losses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--delay", type=int, default=4)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rho", type=float, default=0.3)
    ap.add_argument("--sample", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    print(f"arch {cfg.name} (reduced), delay tau={args.delay}")

    fresh = run(cfg, O.adamw(args.lr, max_grad_norm=1.0), args.steps, args.sample)
    stale = run(
        cfg,
        O.delayed_gradient(O.adamw(args.lr, max_grad_norm=1.0), args.delay),
        args.steps, args.sample,
    )
    lr_scaled = args.lr * O.staleness_step_scale(args.delay, args.rho)
    scaled = run(
        cfg,
        O.delayed_gradient(O.adamw(lr_scaled, max_grad_norm=1.0), args.delay),
        args.steps, args.sample,
    )

    def summarize(tag, l):
        print(f"  {tag:12s} loss: start {l[:5].mean():.3f} -> "
              f"end {l[-10:].mean():.3f} (min {l.min():.3f})")

    summarize("fresh", fresh)
    summarize("stale", stale)
    summarize("stale+prop1", scaled)
    def noise(l):
        return float(np.std(np.diff(l[len(l) // 2:])))

    print(f"\nstep-to-step noise: fresh {noise(fresh):.3f}  "
          f"stale {noise(stale):.3f}  stale+prop1 {noise(scaled):.3f}")
    print("expected (paper conclusion 2): fresh converges fastest; plain "
          "stale is noisier and diverges as tau grows; stale+prop1 trades "
          "a smaller step for stability — slower at short horizons, but it "
          "is the setting that keeps scaling to more workers.")


if __name__ == "__main__":
    main()
