"""End-to-end asynch-SGBDT training run — the paper's efficiency-experiment
pipeline on the parameter-server engine: realistic delay schedules from the
cluster simulator, held-out evaluation, and checkpointing.

    PYTHONPATH=src python examples/train_asynch_sgbdt.py \
        [--trees 200] [--workers 16] [--rate 0.8] [--full]
"""
import argparse
import time

import numpy as np

import repro.data as D
from repro.checkpoint import CheckpointManager
from repro.core.sgbdt import SGBDTConfig, train_loss
from repro.core.simulator import ClusterSpec, simulate_async
from repro.objectives import get_objective
from repro.ps import Trainer
from repro.trees import forest_predict
from repro.trees.learner import LearnerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trees", type=int, default=200)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.8)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--step", type=float, default=0.15)
    ap.add_argument("--full", action="store_true",
                    help="paper scale: 400 trees, 512-leaf trees")
    ap.add_argument("--ckpt", default="experiments/ckpt_gbdt")
    ap.add_argument("--objective", default="logistic",
                    help="objective registry spec (see repro.objectives); "
                         "this example's dataset/accuracy is binary")
    args = ap.parse_args()
    if args.full:
        args.trees, args.depth = 400, 9

    # ------------------------------------------------------------- dataset
    n = 6_000
    data_all = D.make_sparse_classification(n, 1_200, 20, seed=1)
    # 80/20 split on the binned matrix
    n_tr = int(n * 0.8)
    tr = data_all._replace(
        bins=data_all.bins[:n_tr], labels=data_all.labels[:n_tr],
        multiplicity=data_all.multiplicity[:n_tr],
    )
    te_bins, te_y = data_all.bins[n_tr:], np.asarray(data_all.labels[n_tr:])

    obj = get_objective(args.objective)
    cfg = SGBDTConfig(
        n_trees=args.trees, step_length=args.step, sampling_rate=args.rate,
        objective=args.objective,
        learner=LearnerConfig(depth=args.depth, n_bins=64, feature_fraction=0.8),
    )

    # ------------------------------------ realistic schedule from simulator
    spec = ClusterSpec(
        n_workers=args.workers, t_build=0.1, t_comm=0.01, t_server=0.01,
        speed_spread=0.3, comm_cv=0.5, seed=42,
    )
    sim = simulate_async(spec, args.trees)
    print(f"simulated {args.workers}-worker cluster: "
          f"mean staleness {sim.mean_staleness:.1f}, max {sim.max_staleness}, "
          f"makespan {sim.makespan:.1f}s, server busy {sim.server_busy_frac:.0%}")

    # --------------------------------------------------------------- train
    mgr = CheckpointManager(args.ckpt, save_every=50, keep=2)

    def on_eval(st, j):
        tr_loss = float(train_loss(cfg, tr, st))
        pred = obj.link(forest_predict(st.forest, te_bins))
        acc = float(np.mean((np.asarray(pred) > 0.5) == te_y))
        print(f"  tree {j:4d}: train loss {tr_loss:.4f}  test acc {acc:.3f}")
        mgr.maybe_save(j, st._asdict())

    t0 = time.time()
    state = Trainer(cfg).train(
        tr, sim.schedule, seed=0, eval_every=25, eval_fn=on_eval
    )
    print(f"trained {args.trees} trees in {time.time()-t0:.1f}s "
          f"(CPU; schedule from the simulated cluster)")

    pred = obj.link(forest_predict(state.forest, te_bins))
    acc = float(np.mean((np.asarray(pred) > 0.5) == te_y))
    print(f"final test accuracy: {acc:.3f}")
    step, restored = mgr.restore_latest(state._asdict())
    print(f"checkpoint restore OK from step {step}")


if __name__ == "__main__":
    main()
