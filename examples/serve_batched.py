"""Batched serving demo: the wave engine over any assigned architecture.

    PYTHONPATH=src python examples/serve_batched.py \
        [--arch zamba2-1.2b] [--requests 10] [--slots 4]
"""
import argparse
import time

import jax
import numpy as np

import repro.configs as configs
import repro.models as M
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, slots=args.slots,
        max_len=args.prompt_len + args.gen,
    )

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        r = Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(
                np.int32
            ),
            max_new_tokens=args.gen,
        )
        if cfg.family in ("vlm", "audio"):
            r.media = (
                rng.standard_normal((cfg.n_media_tokens, cfg.d_model)) * 0.02
            ).astype(np.float32)
        reqs.append(r)

    t0 = time.time()
    outs = eng.run(reqs)
    dt = time.time() - t0
    tot = sum(len(c.tokens) for c in outs)
    print(f"{cfg.name}: {len(outs)} requests, {tot} tokens in {dt:.2f}s "
          f"({tot/dt:.1f} tok/s incl. compile)")
    for c in outs[:3]:
        print(f"  req {c.uid}: {c.tokens[:10].tolist()} "
              f"(prefill {c.prefill_s:.2f}s decode {c.decode_s:.2f}s)")


if __name__ == "__main__":
    main()
